"""End-to-end training driver.

Runs any registered architecture (reduced or full config) with: the
partial-manual train step (paper's collective in the gradient path where the
mesh has >1 DP rank), the synthetic deterministic data pipeline, async
checkpointing with exact resume, and the fault-tolerance supervisor.

CPU quickstart (the examples call this):
  PYTHONPATH=src python -m repro.launch.train --arch granite_3_8b --reduced \
      --steps 50 --seq-len 128 --global-batch 8

Multi-device (8 virtual hosts):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.train --arch minicpm_2b --reduced --steps 30 \
      --mesh 4x2
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.checkpoint.checkpointing import (CheckpointManager, latest_step,
                                            restore)
from repro.configs.base import get_config, get_parallel
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import step_fns
from repro.launch.mesh import make_mesh
from repro.models import transformer as tf
from repro.optim.optimizers import adamw, cosine_schedule, wsd_schedule
from repro.runtime.fault_tolerance import HostFailure, run_with_restarts


def autotune_warmup(mesh, pcfg, params, leaf_specs=None, *, reps: int = 3,
                    max_buckets: int = 4, verbose: bool = True) -> list:
    """Per-mesh autotune warm-up: measure the collective candidates at the
    ACTUAL gradient bucket sizes on this mesh's data-parallel axes, before
    step 0, and record the winners in the on-disk autotune cache.

    Bucket sizes come from :func:`repro.core.collectives.bucket_sizes` over
    the real parameter pytree — pass ``leaf_specs`` (the params'
    PartitionSpecs) so the sharding-kind partition matches what
    ``bucketed_all_reduce`` issues at trace time; each (axis, bucket) pair
    is timed on a dedicated one-axis mesh built from the devices that
    actually sit along that axis (other axes pinned at coordinate 0 — the
    links the training reduction crosses). Winners are keyed by
    ``(p, nbytes, dtype, comm_model.name)``, exactly the key
    ``CollectiveConfig(method="auto")`` probes at trace time, so the very
    first training step resolves from measurements — the ROADMAP's closed
    loop. Candidate failures are skipped by the tuner; this hook never
    raises on an unmeasurable candidate.

    Key-collision caveat: the cache key does not carry the axis, so when two
    DP axes have the SAME size they share keys. Axes are therefore tuned
    innermost-first ('data', then 'pod'), letting the slowest fabric's
    winner overwrite on collision — a slow-link winner replays safely (if
    suboptimally) on fast links, while the reverse can collapse. Distinct
    per-axis results need distinct ``comm_model`` names (one config per
    fabric), which is also what prices the auto switch correctly.

    Returns ``[(axis, nbytes, TuneResult), ...]`` for logging.
    """
    import time

    from jax.sharding import PartitionSpec as _P

    from repro import compat
    from repro.core import autotune, collectives
    from repro.core.topology import resolve_levels

    cfg = pcfg.collective
    # innermost (fast) first: on key collisions the slow axis wins
    dp_axes = [a for a in ("data", "pod")
               if a in mesh.axis_names and mesh.shape[a] > 1]
    sizes = collectives.bucket_sizes(
        params, cfg.bucket_bytes, leaf_specs=leaf_specs,
        n_model=dict(mesh.shape).get("model"))
    # largest buckets dominate step time; bound warm-up cost
    sizes = sorted(set(sizes), key=lambda t: -t[0])[:max_buckets]
    results = []
    for ax in dp_axes:
        p = mesh.shape[ax]
        pos = mesh.axis_names.index(ax)
        sel = [0] * mesh.devices.ndim
        sel[pos] = slice(None)
        axis_devs = mesh.devices[tuple(sel)]
        tune_mesh = compat.make_mesh((p,), (ax,), devices=axis_devs)
        algorithms = autotune._ALGORITHMS
        if resolve_levels(p, cfg.hier_spec) is not None:
            algorithms = algorithms + ("hier",)
        for n, dtype in sizes:
            nbytes = n * dtype.itemsize
            X = jnp.zeros((n,), dtype)

            def runner(algo, b, _X=X, _p=p, _ax=ax, _mesh=tune_mesh):
                compress = algo.endswith(autotune.COMPRESSED_SUFFIX)
                base = algo[:-len(autotune.COMPRESSED_SUFFIX)] if compress \
                    else algo
                ccfg = dataclasses.replace(cfg, method=base,
                                           num_blocks=int(b),
                                           compress_inter_group=compress)
                f = jax.jit(compat.shard_map(
                    lambda x: collectives.all_reduce(x, _ax, _p, ccfg),
                    mesh=_mesh, in_specs=_P(), out_specs=_P(),
                    check_vma=False))
                f(_X).block_until_ready()  # compile + warm
                ts = []
                for _ in range(reps):
                    t0 = time.perf_counter()
                    f(_X).block_until_ready()
                    ts.append(time.perf_counter() - t0)
                return min(ts)

            res = autotune.tune(
                runner, p, nbytes, str(jnp.dtype(dtype)),
                cfg.comm_model.name, cfg.comm_model,
                algorithms=algorithms, group_size=cfg.hier_spec,
                compress_inter_group=cfg.compress_inter_group)
            results.append((ax, nbytes, res))
            if verbose:
                tag = "+bf16" if res.compressed else ""
                print(f"warmup[{ax} p={p}] {nbytes}B {jnp.dtype(dtype).name}"
                      f" -> {res.algorithm}{tag}/b={res.num_blocks}"
                      f" ({res.time_s * 1e6:.0f}us)")
    return results


def build_optimizer(arch_mod, lr: float, steps: int):
    sched_name = getattr(arch_mod, "TRAIN_SCHEDULE", "cosine")
    warmup = max(5, steps // 20)
    if sched_name == "wsd":
        sched = wsd_schedule(lr, warmup, int(steps * 0.7),
                             steps - warmup - int(steps * 0.7) or 1)
    else:
        sched = cosine_schedule(lr, warmup, steps)
    return adamw(sched)


def train_loop(args, fail_at: int | None = None) -> dict:
    """One training attempt; raises HostFailure at step ``fail_at`` (tests)."""
    from repro.configs import base as cfgbase

    mesh_shape = tuple(int(x) for x in args.mesh.split("x"))
    n_need = int(np.prod(mesh_shape))
    axes = ("data", "model")[-len(mesh_shape):] if len(mesh_shape) == 2 \
        else ("pod", "data", "model")
    mesh = make_mesh(mesh_shape, axes)
    cfg = get_config(args.arch, reduced=args.reduced)
    pcfg = get_parallel(args.arch)
    if args.collective:
        pcfg = dataclasses.replace(
            pcfg, collective=dataclasses.replace(pcfg.collective,
                                                 method=args.collective))
    arch_mod = cfgbase.get_arch(args.arch)
    optimizer = build_optimizer(arch_mod, args.lr, args.steps)
    step, sh = step_fns.make_train_step(cfg, pcfg, mesh, optimizer,
                                        accum=args.accum)

    params = tf.init_params(jax.random.PRNGKey(args.seed), cfg)
    params = jax.device_put(params, step_fns._named(mesh, sh["params"]))
    if getattr(args, "autotune_warmup", False):
        autotune_warmup(mesh, pcfg, params, leaf_specs=sh["params"])
    opt_state = jax.device_put(sh["opt_init"](params),
                               step_fns._named(mesh, sh["opt"]))

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                      global_batch=args.global_batch, seed=args.seed)
    ds = SyntheticLM(dcfg)
    mgr = CheckpointManager(args.ckpt_dir, keep=2) if args.ckpt_dir else None

    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        state_like = {"params": params, "opt": opt_state}
        state, extra, start = restore(args.ckpt_dir, state_like)
        params = jax.device_put(state["params"],
                                step_fns._named(mesh, sh["params"]))
        opt_state = jax.device_put(state["opt"],
                                   step_fns._named(mesh, sh["opt"]))
        print(f"resumed from step {start}")

    bsharding = NamedSharding(mesh, sh["batch"])
    hist = []
    t0 = time.time()
    for i in range(start, args.steps):
        batch = ds.batch_at(i)
        batch = jax.device_put(batch, bsharding)
        params, opt_state, vec = step(params, opt_state, batch)
        if fail_at is not None and i == fail_at:
            raise HostFailure(0, f"injected failure at step {i}")
        if i % args.log_every == 0 or i == args.steps - 1:
            v = np.asarray(vec)
            hist.append((i, float(v[0])))
            print(f"step {i:5d} loss {v[0]:.4f} ce {v[1]:.4f} "
                  f"gnorm {v[3]:.3f} ({time.time()-t0:.1f}s)")
        if mgr and i and i % args.ckpt_every == 0:
            mgr.save_async(i + 1, {"params": params, "opt": opt_state},
                           extra={"data_step": i + 1})
    if mgr:
        mgr.save_async(args.steps, {"params": params, "opt": opt_state},
                       extra={"data_step": args.steps})
        mgr.wait()
        mgr.close()
    return {"history": hist, "final_loss": hist[-1][1] if hist else None,
            "params": params}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--mesh", default="1x1", help="e.g. 4x2 = data x model")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--collective", default=None,
                    help="override: dptree|sptree|redbcast|ring|hier|psum|auto")
    ap.add_argument("--autotune-warmup", action="store_true",
                    help="before step 0, measure the collective candidates at "
                         "the actual gradient bucket sizes on this mesh and "
                         "cache the winners for method='auto'")
    ap.add_argument("--autotune-cache", default=None, metavar="PATH",
                    help="per-deployment autotune cache file; overrides "
                         "REPRO_AUTOTUNE_CACHE and the XDG default — both "
                         "the warm-up's writes and method='auto' consults "
                         "go through it (one file per mesh/deployment)")
    ap.add_argument("--max-restarts", type=int, default=3)
    args = ap.parse_args(argv)
    if args.autotune_cache:
        from repro.core import autotune
        autotune.set_cache_path(args.autotune_cache)

    out = run_with_restarts(lambda attempt: train_loop(args),
                            max_restarts=args.max_restarts)
    print(f"done. final loss {out['final_loss']:.4f} "
          f"(restarts: {out['restarts']})")
    return out


if __name__ == "__main__":
    main()

"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run sets the placeholder device count
before any jax initialization.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) single pod or (2,16,16) two pods — the graded target meshes.

    Works when the process exposes more devices than the mesh needs (the
    dry-run forces 512 host devices; the single-pod mesh takes the first 256).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    need = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(f"mesh {shape} needs {need} devices, "
                           f"have {len(devs)} (set XLA_FLAGS host device count)")
    from repro import compat
    return compat.make_mesh(shape, axes, devices=devs[:need])


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / local runs), Auto axis types."""
    from repro import compat
    return compat.make_mesh(shape, axes)


def make_tp_mesh(tp: int):
    """Tensor-parallel serving mesh: ``tp`` devices on a single ``'tp'`` axis.

    Deliberately one-axis: the TP step builders wrap the model body in a
    shard_map manual over EVERY mesh axis, and on the container's old jax a
    fully-manual region is the one place ``ppermute`` (hence the dptree /
    sptree / ring schedule collectives) still lowers — any auto axis in the
    mesh would force ``collectives.all_reduce`` down its psum fallback (see
    ``repro/compat.py``). Replica parallelism composes at the process level
    (``serving.fleet``), not as a second axis here.
    """
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    devs = jax.devices()
    if len(devs) < tp:
        raise RuntimeError(f"tp={tp} needs {tp} devices, have {len(devs)} "
                           "(set XLA_FLAGS=--xla_force_host_platform_device_count)")
    from repro import compat
    return compat.make_mesh((tp,), ("tp",), devices=devs[:tp])


def make_local_mesh():
    """Whatever this process has (1 CPU device in the container)."""
    n = len(jax.devices())
    return make_mesh((1, n), ("data", "model")) if n == 1 else \
        make_mesh((n, 1), ("data", "model"))

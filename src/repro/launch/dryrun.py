import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh) cell.

MUST be imported/run before any other jax initialization — the two lines above
create 512 placeholder host devices so ``jax.make_mesh`` can build the
production meshes: (16,16)=256 chips single-pod and (2,16,16)=512 chips
multi-pod. For every cell we record:

* ``memory_analysis()``  — proves the program fits per-chip HBM,
* ``cost_analysis()``    — raw HLO FLOPs/bytes (scan bodies counted once —
  see analysis/flops.py for why the roofline uses the analytic model),
* collective-op operand bytes parsed from the compiled HLO text, with
  per-computation while-loop trip-count multipliers,
* the three analytic roofline terms (compute/memory/collective).

Usage:
  python -m repro.launch.dryrun --cell <arch>:<shape>:<single|multi>  # one cell
  python -m repro.launch.dryrun --all [--jobs 8] [--out results.json] # sweep
"""

import argparse
import dataclasses
import json
import re
import subprocess
import sys
import time
from collections import Counter

# --------------------------------------------------------------------------

def run_cell(arch: str, shape: str, multi_pod: bool) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.analysis import flops as fl
    from repro.analysis.roofline import parse_collective_bytes
    from repro.configs.base import (SHAPES, get_config, get_parallel,
                                    input_specs, supports_shape)
    from repro.launch import step_fns
    from repro.launch.mesh import make_production_mesh
    from repro.models import transformer as tf

    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if not supports_shape(arch, shape):
        rec.update(status="skipped",
                   reason="long_500k requires sub-quadratic attention")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    suite = SHAPES[shape]
    cfg = get_config(arch)
    cfg = dataclasses.replace(cfg, param_dtype=jnp.bfloat16)
    if suite.kind == "decode" and cfg.n_kv_heads * cfg.hdim >= 2048:
        # MHA-heavy archs (minicpm kv=36): int8 KV cache halves the
        # dominant decode memory term (see EXPERIMENTS.md §Perf)
        cfg = dataclasses.replace(cfg, kv_quant=True)
    pcfg = get_parallel(arch)
    n_chips = int(mesh.devices.size)
    n_model = mesh.shape["model"]
    dp = [a for a in ("pod", "data") if a in mesh.axis_names]

    t0 = time.time()
    if suite.kind == "train":
        # microbatches of 1 sequence/chip bound the remat-saved activation
        # footprint (EXPERIMENTS.md §Perf M5); clamp to the per-DP-rank batch
        n_dp = int(np.prod([mesh.shape[a] for a in dp]))
        accum = max(1, min(16, suite.global_batch // n_dp))
        step, sh = step_fns.make_train_step(cfg, pcfg, mesh, accum=accum)
        zeros_p = jax.eval_shape(
            lambda: tf.init_params(jax.random.PRNGKey(0), cfg))
        p_abs = jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(
                l.shape, l.dtype, sharding=NamedSharding(mesh, s)),
            zeros_p, sh["params"],
            is_leaf=lambda v: hasattr(v, "shape") and not isinstance(v, dict))
        zeros_o = jax.eval_shape(sh["opt_init"], zeros_p)
        o_abs = jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(
                l.shape, l.dtype, sharding=NamedSharding(mesh, s)),
            zeros_o, sh["opt"],
            is_leaf=lambda v: hasattr(v, "shape") and not isinstance(v, dict))
        bspec = sh["batch"]
        b_abs = {k: jax.ShapeDtypeStruct(
                     v.shape, v.dtype,
                     sharding=NamedSharding(
                         mesh, P(*((tuple(bspec) if bspec else ())
                                   + (None,) * (v.ndim - 1)))))
                 for k, v in input_specs(cfg, suite).items()}
        lowered = step.lower(p_abs, o_abs, b_abs)
    elif suite.kind == "prefill":
        step, sh = step_fns.make_prefill_step(cfg, pcfg, mesh, suite)
        zeros_p = jax.eval_shape(
            lambda: tf.init_params(jax.random.PRNGKey(0), cfg))
        p_abs = jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(
                l.shape, l.dtype, sharding=NamedSharding(mesh, s)),
            zeros_p, sh["params"],
            is_leaf=lambda v: hasattr(v, "shape") and not isinstance(v, dict))
        bspec = sh["batch"]
        b_abs = {k: jax.ShapeDtypeStruct(
                     v.shape, v.dtype,
                     sharding=NamedSharding(
                         mesh, P(*((tuple(bspec) if bspec else ())
                                   + (None,) * (v.ndim - 1)))))
                 for k, v in input_specs(cfg, suite).items()}
        lowered = step.lower(p_abs, b_abs)
    else:  # decode
        step, sh = step_fns.make_serve_step(cfg, pcfg, mesh, suite)
        zeros_p = jax.eval_shape(
            lambda: tf.init_params(jax.random.PRNGKey(0), cfg))
        p_abs = jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(
                l.shape, l.dtype, sharding=NamedSharding(mesh, s)),
            zeros_p, sh["params"],
            is_leaf=lambda v: hasattr(v, "shape") and not isinstance(v, dict))
        caches = tf.init_cache(cfg, suite.global_batch, suite.seq_len,
                               abstract=True)
        c_abs = jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(
                l.shape, l.dtype, sharding=NamedSharding(mesh, s)),
            caches, sh["cache"],
            is_leaf=lambda v: hasattr(v, "shape") and not isinstance(v, dict))
        bspec = sh["batch"]
        b_abs = {k: jax.ShapeDtypeStruct(
                     v.shape, v.dtype,
                     sharding=NamedSharding(
                         mesh, P(*((tuple(bspec) if bspec else ())
                                   + (None,) * (v.ndim - 1)))))
                 for k, v in input_specs(cfg, suite).items()}
        lowered = step.lower(p_abs, b_abs, c_abs)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    colls = parse_collective_bytes(hlo)
    cost = fl.cell_cost(cfg, suite, n_chips, n_model, pcfg.dp_mode)
    p_data = mesh.shape.get("data", 1)
    p_pod = mesh.shape.get("pod", 1)
    terms = fl.roofline_terms(cost, n_chips, p_data, p_pod, pcfg.dp_mode)

    # donation aliases outputs into arguments on TPU; the CPU backend ignores
    # donate_argnums, so arg+out double-counts there. The TPU-realistic
    # footprint is max(arg, out) + temp.
    per_chip_bytes = (max(mem.argument_size_in_bytes,
                          mem.output_size_in_bytes)
                      + mem.temp_size_in_bytes)
    rec.update(
        status="ok",
        lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
        memory=dict(argument=mem.argument_size_in_bytes,
                    output=mem.output_size_in_bytes,
                    temp=mem.temp_size_in_bytes,
                    per_chip_total=per_chip_bytes,
                    fits_16GB=bool(per_chip_bytes < 16e9)),
        cost_analysis_raw=dict(
            flops=float(ca.get("flops", 0.0)),
            bytes_accessed=float(ca.get("bytes accessed", 0.0))),
        collectives=colls,
        roofline=terms,
        params_total=cfg.param_count(),
        params_active=cfg.active_param_count(),
    )
    return rec


# --------------------------------------------------------------------------

def main():
    from repro.configs.base import ARCHS, SHAPES

    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", help="arch:shape:single|multi")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=6)
    ap.add_argument("--out", default="dryrun_results.json")
    args = ap.parse_args()

    if args.cell:
        arch, shape, meshk = args.cell.split(":")
        try:
            rec = run_cell(arch, shape, meshk == "multi")
        except Exception as e:  # a failing cell is a bug — record it loudly
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if meshk == "multi" else "16x16",
                   "status": "error", "error": f"{type(e).__name__}: {e}"}
        print(json.dumps(rec))
        sys.exit(0 if rec["status"] in ("ok", "skipped") else 1)

    if not args.all:
        sys.exit("need --cell or --all")

    cells = [(a, s, m) for a in ARCHS for s in SHAPES for m in ("single",
                                                                "multi")]
    done = {}
    if os.path.exists(args.out):
        for r in json.load(open(args.out)):
            done[(r["arch"], r["shape"], r["mesh"])] = r
    pending = [(a, s, m) for (a, s, m) in cells
               if ((a, s, "2x16x16" if m == "multi" else "16x16") not in done
                   or done[(a, s, "2x16x16" if m == "multi" else "16x16")]
                   ["status"] == "error")]
    print(f"{len(pending)} cells to run ({len(done)} cached)")
    procs: dict = {}
    results = dict(done)

    def launch(cell):
        a, s, m = cell
        return subprocess.Popen(
            [sys.executable, "-m", "repro.launch.dryrun", "--cell",
             f"{a}:{s}:{m}"],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
            env={**os.environ, "PYTHONPATH": "src"})

    queue = list(pending)
    while queue or procs:
        while queue and len(procs) < args.jobs:
            cell = queue.pop(0)
            procs[launch(cell)] = cell
        for pr in list(procs):
            if pr.poll() is None:
                continue
            cell = procs.pop(pr)
            out, err = pr.communicate()
            try:
                rec = json.loads(out.strip().splitlines()[-1])
            except Exception:
                rec = {"arch": cell[0], "shape": cell[1],
                       "mesh": "2x16x16" if cell[2] == "multi" else "16x16",
                       "status": "error",
                       "error": (err or out)[-2000:]}
            results[(rec["arch"], rec["shape"], rec["mesh"])] = rec
            n_ok = sum(1 for r in results.values()
                       if r["status"] in ("ok", "skipped"))
            print(f"[{n_ok}/{len(cells)}] {rec['arch']}:{rec['shape']}:"
                  f"{rec['mesh']} -> {rec['status']}"
                  + (f" ({rec.get('error', '')[:120]})"
                     if rec["status"] == "error" else ""))
            with open(args.out, "w") as f:
                json.dump(list(results.values()), f, indent=1)
        time.sleep(0.3)
    bad = [r for r in results.values() if r["status"] == "error"]
    print(f"done: {len(results) - len(bad)} ok/skipped, {len(bad)} errors")
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()

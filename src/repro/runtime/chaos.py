"""Deterministic fault injection for the runtime and serving stacks.

Every failure scenario the fleet must survive — replica death, heartbeat
flapping, straggler ticks, NaN/Inf-poisoned logits, corrupted autotune
cache entries — is described by a :class:`FaultPlan`: an immutable schedule
of :class:`Fault` events pinned to engine *ticks*. A plan is either written
out explicitly (regression tests pin exact scenarios) or derived from a
seed (:meth:`FaultPlan.seeded`), so every scenario is a pure function of
``(seed, tick)`` and replays bit-for-bit in tests, benches, and the
``serve.py --chaos-seed`` demo.

The :class:`FaultInjector` is a *stateless* view over a plan: all queries
(``silenced``, ``skips_tick``, ``poisons``, ...) depend only on the plan
and the tick argument, never on call order. The injector decides *what*
goes wrong and *when*; the consequences run through the production paths —
a silenced replica simply stops heartbeating (the
:class:`~repro.runtime.fault_tolerance.HeartbeatMonitor` state machine does
the rest), a poisoned cache flows through the real jitted decode step and
trips the engine's non-finite-logits guard, a corrupted autotune entry
exercises the cache's degrade-never-raise contract.

Fault kinds:

``kill``      the replica stops beating at ``tick`` and never returns.
``flap``      the replica goes silent for ``duration`` ticks, then resumes
              beating — below the monitor's death threshold it survives
              (suspect -> alive); above it, it dies and later REJOINS.
``straggle``  for ``duration`` ticks the replica runs ``factor``x slower
              (it still heartbeats; in the tick simulation it processes
              only every ``round(factor)``-th tick).
``poison``    at ``tick`` the replica's busiest decode slot gets NaN
              written into its cache rows — the next decode produces
              non-finite logits and the engine must quarantine, not commit.
``corrupt``   an autotune-cache entry is corrupted on disk (see
              :func:`corrupt_autotune_cache`) — consumers must degrade to
              the cost-model switch, never raise.
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np

__all__ = ["KINDS", "Fault", "FaultPlan", "FaultInjector", "poison_slot",
           "corrupt_autotune_cache"]

KINDS = ("kill", "flap", "straggle", "poison", "corrupt")


@dataclasses.dataclass(frozen=True, order=True)
class Fault:
    """One scheduled failure event at a tick boundary."""

    tick: int
    kind: str = "kill"
    replica: int = 0
    duration: int = 0        # flap: silent ticks; straggle: affected ticks
    factor: float = 2.0      # straggle: slowdown multiplier

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; want {KINDS}")
        if self.tick < 0 or self.replica < 0:
            raise ValueError(f"tick/replica must be >= 0, got {self}")
        if self.kind in ("flap", "straggle") and self.duration < 1:
            raise ValueError(f"{self.kind} needs duration >= 1, got {self}")
        if self.factor < 1.0:
            raise ValueError(f"factor must be >= 1, got {self.factor}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, tick-sorted schedule of faults."""

    faults: tuple = ()

    def __post_init__(self):
        fs = tuple(sorted(self.faults))
        for f in fs:
            if not isinstance(f, Fault):
                raise TypeError(f"FaultPlan wants Fault entries, got {f!r}")
        object.__setattr__(self, "faults", fs)

    @classmethod
    def seeded(cls, seed: int, *, n_replicas: int, horizon: int,
               n_faults: int = 3,
               kinds=("kill", "flap", "straggle", "poison")) -> "FaultPlan":
        """A deterministic plan: the same ``(seed, n_replicas, horizon)``
        always yields the same schedule. Replica 0 is never killed outright
        so the fleet always keeps a survivor to fail over to."""
        if n_replicas < 1 or horizon < 2:
            raise ValueError("need n_replicas >= 1 and horizon >= 2")
        rng = np.random.default_rng(seed)
        faults = []
        for _ in range(n_faults):
            kind = str(kinds[int(rng.integers(len(kinds)))])
            lo = 1 if n_replicas > 1 and kind in ("kill", "flap") else 0
            replica = int(rng.integers(lo, n_replicas)) if n_replicas > lo \
                else 0
            tick = int(rng.integers(1, horizon))
            duration = (int(rng.integers(1, max(2, horizon // 2)))
                        if kind in ("flap", "straggle") else 0)
            factor = (float(2 ** int(rng.integers(1, 4)))
                      if kind == "straggle" else 2.0)
            faults.append(Fault(tick, kind, replica, duration, factor))
        return cls(tuple(faults))

    def at(self, tick: int) -> tuple:
        return tuple(f for f in self.faults if f.tick == tick)

    def __iter__(self):
        return iter(self.faults)

    def __len__(self):
        return len(self.faults)


class FaultInjector:
    """Stateless query interface over a :class:`FaultPlan`.

    Every method is a pure function of ``(plan, tick[, replica])`` — no
    internal counters, no call-order dependence — which is what makes a
    chaos run replayable from its seed alone."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan

    def at(self, tick: int) -> tuple:
        return self.plan.at(tick)

    def silenced(self, tick: int, replica: int) -> bool:
        """True while the replica's process is stalled: killed for good, or
        inside a flap window. A silenced replica neither ticks nor beats."""
        for f in self.plan:
            if f.replica != replica:
                continue
            if f.kind == "kill" and tick >= f.tick:
                return True
            if f.kind == "flap" and f.tick <= tick < f.tick + f.duration:
                return True
        return False

    def straggle_factor(self, tick: int, replica: int) -> float:
        """The slowdown multiplier in effect (1.0 = healthy)."""
        fac = 1.0
        for f in self.plan:
            if (f.kind == "straggle" and f.replica == replica
                    and f.tick <= tick < f.tick + f.duration):
                fac = max(fac, f.factor)
        return fac

    def skips_tick(self, tick: int, replica: int) -> bool:
        """Tick-simulation form of a straggler: a ``factor``-x slower
        replica advances only every ``round(factor)``-th tick of the
        window (it keeps heartbeating — stragglers are slow, not dead)."""
        for f in self.plan:
            if (f.kind == "straggle" and f.replica == replica
                    and f.tick <= tick < f.tick + f.duration):
                if (tick - f.tick) % max(1, int(round(f.factor))) != 0:
                    return True
        return False

    def poisons(self, tick: int, replica: int) -> bool:
        return any(f.kind == "poison" and f.replica == replica
                   and f.tick == tick for f in self.plan)


def poison_slot(caches, slot: int):
    """NaN-poison one slot's cache rows (stacked per-slot cache pytree).

    Floating-point leaves with a batch dimension get their ``slot`` row set
    to NaN; position counters and integer (quantized) leaves are left
    alone, so the row still *looks* live — the poison surfaces exactly
    where it would on real hardware: as non-finite decode logits, which the
    engine's guard must refuse to commit."""
    import jax
    import jax.numpy as jnp

    def leaf(v):
        if v.ndim < 2 or not jnp.issubdtype(v.dtype, jnp.floating):
            return v
        return v.at[:, slot].set(jnp.nan)

    return jax.tree.map(leaf, caches)


def corrupt_autotune_cache(path: str, seed: int = 0) -> str:
    """Deterministically corrupt an autotune cache file in place.

    Scrambles one existing entry (if any) into semantic garbage — an
    unknown algorithm and a non-positive block count — and appends a
    malformed entry. Returns the corrupted key. The degrade-never-raise
    contract (docs/autotuning.md) requires every consumer to treat such
    entries as cache misses."""
    rng = np.random.default_rng(seed)
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        doc = {"schema": 1, "entries": {}}
    entries = doc.setdefault("entries", {})
    keys = sorted(entries)
    if keys:
        victim = keys[int(rng.integers(len(keys)))]
        entries[victim] = {"algorithm": "zz_bogus", "num_blocks": -7,
                           "time_s": float("1e300")}
    else:
        victim = "p=0|n=0|d=?|t=?"
        entries[victim] = {"algorithm": None, "num_blocks": "many"}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    return victim

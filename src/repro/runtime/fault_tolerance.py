"""Fault-tolerance runtime: failure detection, elastic re-meshing, straggler
mitigation, and a restarting training-loop supervisor.

On a real multi-host deployment the heartbeat transport is the cluster
coordinator (GCS / k8s liveness); here it is injectable so tests can kill
"hosts" deterministically. What matters architecturally:

* the dual-tree topology is parametric in ``p`` — **any** surviving subset of
  hosts re-forms a valid collective schedule in O(p) host time (the paper's
  ``p = 2^h - 2`` balance is a special case, not a requirement), and the
  same property lets the schedule *grow* back over a rejoined host;
* the data pipeline is stateless-indexable, so a re-shard after shrink
  replays the exact global batch stream;
* checkpoints publish atomically, so restart-from-latest is always consistent.

The :class:`HeartbeatMonitor` is a flap-tolerant state machine
(docs/robustness.md):

    ALIVE --missed deadline--> SUSPECT --``misses`` deadlines--> DEAD
      ^                           |                               |
      |____resumed beats__________|        resumed beats + backoff|
      |___________________________________________________________|
                              (rejoin)

``misses=1`` (the default) collapses SUSPECT into DEAD — the pre-flap
behavior, byte-compatible with existing callers. A dropped host that beats
again becomes *rejoinable* once it has beaten steadily for its backoff
window, which doubles with every drop (a flapping host earns longer
probation each time).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro.core import cost_model as cm
from repro.core.topology import build_dual_tree

__all__ = ["HostFailure", "HeartbeatMonitor", "ElasticPlan", "plan_remesh",
           "StragglerTuner", "run_with_restarts"]


class HostFailure(RuntimeError):
    """Raised (or injected) when hosts miss their heartbeat deadline.

    ``host`` is the first (lowest-id) dead host — kept for callers that
    predate simultaneous-death reporting; ``hosts`` is the FULL dead set
    found by the same poll, which is what fleet failover must act on."""

    def __init__(self, host: int, msg: str = "", hosts=None):
        self.host = host
        self.hosts = tuple(hosts) if hosts else (host,)
        if not msg:
            ids = ", ".join(str(h) for h in self.hosts)
            noun = "hosts" if len(self.hosts) > 1 else "host"
            msg = f"{noun} {ids} failed heartbeat"
        super().__init__(msg)


class HeartbeatMonitor:
    """Tracks last-seen timestamps per host; see the module docstring for
    the ALIVE/SUSPECT/DEAD/rejoin state machine.

    ``timeout_s`` is one missed deadline; a host is SUSPECT past
    ``timeout_s`` and DEAD past ``misses * timeout_s``. ``rejoin_backoff_s``
    is the base probation a dropped host must beat through before
    :meth:`rejoinable` reports it (doubled per drop, capped at
    ``rejoin_cap_s``); 0 means a single resumed beat suffices."""

    def __init__(self, n_hosts: int, timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic, *,
                 misses: int = 1, rejoin_backoff_s: float = 0.0,
                 rejoin_cap_s: float = 3600.0):
        if misses < 1:
            raise ValueError(f"misses must be >= 1, got {misses}")
        if timeout_s <= 0:
            raise ValueError(f"timeout_s must be > 0, got {timeout_s}")
        self.n_hosts = n_hosts
        self.timeout_s = timeout_s
        self.misses = misses
        self.rejoin_backoff_s = rejoin_backoff_s
        self.rejoin_cap_s = rejoin_cap_s
        self._clock = clock
        now = clock()
        self._last = {h: now for h in range(n_hosts)}
        self._drops: dict = {}    # host -> times dropped (persists forever)
        self._gone: dict = {}     # dropped host -> {"resumed": t|None, "last": t}

    def beat(self, host: int):
        if host in self._gone:
            # a dropped host talking again: start (or continue) probation
            info = self._gone[host]
            now = self._clock()
            if info["resumed"] is None:
                info["resumed"] = now
            info["last"] = now
            return
        self._last[host] = self._clock()

    def suspect_hosts(self) -> list:
        """Hosts past one deadline but not yet declared dead (the flap
        grace window; empty when ``misses == 1``)."""
        now = self._clock()
        return sorted(h for h, t in self._last.items()
                      if self.timeout_s < now - t <= self.misses
                      * self.timeout_s)

    def dead_hosts(self) -> list:
        """Every host past ``misses`` deadlines, ascending — one clock
        read, so two hosts that died in the same interval are BOTH reported
        by the same poll (the serving fleet must fail them over together;
        handling one per poll lets orphans be re-placed onto a replica that
        is already dead but not yet detected)."""
        now = self._clock()
        return sorted(h for h, t in self._last.items()
                      if now - t > self.misses * self.timeout_s)

    def check(self):
        dead = self.dead_hosts()
        if dead:
            raise HostFailure(dead[0], hosts=tuple(dead))

    def drop(self, host: int):
        self._last.pop(host, None)
        self._drops[host] = self._drops.get(host, 0) + 1
        self._gone[host] = {"resumed": None, "last": None}
        self.n_hosts -= 1

    def rejoin_backoff(self, host: int) -> float:
        """This host's current probation window (exponential per drop)."""
        k = max(1, self._drops.get(host, 1))
        return min(self.rejoin_cap_s, self.rejoin_backoff_s * 2 ** (k - 1))

    def rejoinable(self) -> list:
        """Dropped hosts that have resumed beating and beaten steadily
        through their backoff window. A host whose resumed beats go stale
        again (flapping during probation) restarts its probation."""
        now = self._clock()
        out = []
        for h in sorted(self._gone):
            info = self._gone[h]
            if info["resumed"] is None:
                continue
            if now - info["last"] > self.timeout_s:
                info["resumed"] = None          # flapped during probation
                continue
            if now - info["resumed"] >= self.rejoin_backoff(h):
                out.append(h)
        return out

    def readmit(self, host: int):
        """Move a rejoinable host back to the alive set."""
        if host not in self._gone:
            raise ValueError(f"host {host} was never dropped")
        del self._gone[host]
        self._last[host] = self._clock()
        self.n_hosts += 1


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Result of re-planning after a membership change."""
    survivors: tuple
    new_p: int
    topology_height: int
    predicted_allreduce_s: float
    new_num_blocks: int


def plan_remesh(survivors, grad_bytes: float,
                model: cm.CommModel = cm.TPU_V5E) -> ElasticPlan:
    """Rebuild the collective plan for the surviving data-parallel ranks.

    The same call re-plans a *grow*: the dual-root tree is parametric in
    ``p``, so a rejoined rank simply yields a taller/wider schedule —
    shrink and grow are the one code path."""
    p = len(survivors)
    topo = build_dual_tree(p)
    b = cm.optimal_blocks(p, grad_bytes, model, "dptree")
    t = cm.dptree_time(p, grad_bytes, b, model)
    return ElasticPlan(tuple(survivors), p, topo.max_depth, t, b)


class StragglerTuner:
    """Pipelined trees are bulk-synchronous per macro-round: one slow link
    stretches every round. When observed step time exceeds the model's
    prediction by ``threshold``, shrink the block count (fewer, larger rounds
    amortize the straggler's per-round latency penalty alpha_hat). When the
    observed times later return to the base model's prediction, re-solve
    back to the unscaled optimum — a transient straggler must not
    permanently pessimize the collective (``recovery`` is the tolerance on
    "returned to prediction")."""

    def __init__(self, p: int, grad_bytes: float,
                 model: cm.CommModel = cm.TPU_V5E, threshold: float = 1.5,
                 window: int = 20, recovery: float = 1.25):
        self.p, self.grad_bytes, self.model = p, grad_bytes, model
        self.threshold = threshold
        self.recovery = recovery
        self.window = window
        self.times: list = []
        self.num_blocks = cm.optimal_blocks(p, grad_bytes, model, "dptree")
        self._opt_blocks = self.num_blocks    # the unscaled-model optimum

    def observe(self, step_time_s: float) -> int:
        self.times.append(step_time_s)
        if len(self.times) >= self.window:
            med = float(np.median(self.times[-self.window:]))
            pred = cm.dptree_time(self.p, self.grad_bytes, self.num_blocks,
                                  self.model)
            if pred > 0 and med > self.threshold * pred:
                # effective alpha grew: re-solve with alpha_hat = alpha*ratio
                ratio = med / pred
                scaled = cm.CommModel(self.model.alpha * ratio,
                                      self.model.beta, self.model.gamma)
                self.num_blocks = max(1, cm.optimal_blocks(
                    self.p, self.grad_bytes, scaled, "dptree"))
                self.times.clear()
            elif (self.num_blocks != self._opt_blocks
                  and med <= self.recovery * pred):
                # observed times match the BASE model again at the current
                # block count: the straggler cleared — undo the ratchet
                self.num_blocks = self._opt_blocks
                self.times.clear()
        return self.num_blocks


def run_with_restarts(loop_fn: Callable[[int], dict], max_restarts: int = 3,
                      *, backoff_s: float = 0.0, backoff_cap_s: float = 60.0,
                      jitter: float = 0.1, seed: int = 0,
                      sleep: Callable[[float], None] = time.sleep):
    """Supervise ``loop_fn(attempt)``; on HostFailure restart from the latest
    checkpoint (loop_fn is responsible for restore-on-entry). Returns the
    final result dict with a ``restarts`` count.

    Between restarts the supervisor waits ``backoff_s * 2**(attempt-1)``
    seconds (capped at ``backoff_cap_s``) plus a DETERMINISTIC jitter
    fraction in ``[0, jitter)`` derived from ``(seed, attempt)`` — restarts
    of a crashed fleet de-synchronize (no thundering-herd re-init) yet
    every run with the same seed replays the same schedule. ``backoff_s=0``
    (the default) restarts immediately, the pre-backoff behavior."""
    attempt = 0
    while True:
        try:
            out = loop_fn(attempt)
            out["restarts"] = attempt
            return out
        except HostFailure:
            attempt += 1
            if attempt > max_restarts:
                raise
            if backoff_s > 0:
                delay = min(backoff_cap_s, backoff_s * 2 ** (attempt - 1))
                frac = float(np.random.default_rng(
                    seed + attempt).uniform(0.0, max(jitter, 0.0)))
                sleep(delay * (1.0 + frac))

"""Fault-tolerance runtime: failure detection, elastic re-meshing, straggler
mitigation, and a restarting training-loop supervisor.

On a real multi-host deployment the heartbeat transport is the cluster
coordinator (GCS / k8s liveness); here it is injectable so tests can kill
"hosts" deterministically. What matters architecturally:

* the dual-tree topology is parametric in ``p`` — **any** surviving subset of
  hosts re-forms a valid collective schedule in O(p) host time (the paper's
  ``p = 2^h - 2`` balance is a special case, not a requirement);
* the data pipeline is stateless-indexable, so a re-shard after shrink
  replays the exact global batch stream;
* checkpoints publish atomically, so restart-from-latest is always consistent.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro.core import cost_model as cm
from repro.core.topology import build_dual_tree

__all__ = ["HostFailure", "HeartbeatMonitor", "ElasticPlan", "plan_remesh",
           "StragglerTuner", "run_with_restarts"]


class HostFailure(RuntimeError):
    """Raised (or injected) when a host misses its heartbeat deadline."""

    def __init__(self, host: int, msg: str = ""):
        self.host = host
        super().__init__(msg or f"host {host} failed heartbeat")


class HeartbeatMonitor:
    """Tracks last-seen timestamps per host; ``check`` raises on timeout."""

    def __init__(self, n_hosts: int, timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.n_hosts = n_hosts
        self.timeout_s = timeout_s
        self._clock = clock
        now = clock()
        self._last = {h: now for h in range(n_hosts)}

    def beat(self, host: int):
        self._last[host] = self._clock()

    def dead_hosts(self) -> list:
        """Every host currently past its deadline, ascending — one clock
        read, so two hosts that died in the same interval are BOTH reported
        by the same poll (the serving fleet must fail them over together;
        handling one per poll lets orphans be re-placed onto a replica that
        is already dead but not yet detected)."""
        now = self._clock()
        return sorted(h for h, t in self._last.items()
                      if now - t > self.timeout_s)

    def check(self):
        dead = self.dead_hosts()
        if dead:
            raise HostFailure(dead[0])

    def drop(self, host: int):
        self._last.pop(host, None)
        self.n_hosts -= 1


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Result of re-planning after a membership change."""
    survivors: tuple
    new_p: int
    topology_height: int
    predicted_allreduce_s: float
    new_num_blocks: int


def plan_remesh(survivors, grad_bytes: float,
                model: cm.CommModel = cm.TPU_V5E) -> ElasticPlan:
    """Rebuild the collective plan for the surviving data-parallel ranks."""
    p = len(survivors)
    topo = build_dual_tree(p)
    b = cm.optimal_blocks(p, grad_bytes, model, "dptree")
    t = cm.dptree_time(p, grad_bytes, b, model)
    return ElasticPlan(tuple(survivors), p, topo.max_depth, t, b)


class StragglerTuner:
    """Pipelined trees are bulk-synchronous per macro-round: one slow link
    stretches every round. When observed step time exceeds the model's
    prediction by ``threshold``, shrink the block count (fewer, larger rounds
    amortize the straggler's per-round latency penalty alpha_hat)."""

    def __init__(self, p: int, grad_bytes: float,
                 model: cm.CommModel = cm.TPU_V5E, threshold: float = 1.5,
                 window: int = 20):
        self.p, self.grad_bytes, self.model = p, grad_bytes, model
        self.threshold = threshold
        self.window = window
        self.times: list = []
        self.num_blocks = cm.optimal_blocks(p, grad_bytes, model, "dptree")

    def observe(self, step_time_s: float) -> int:
        self.times.append(step_time_s)
        if len(self.times) >= self.window:
            med = float(np.median(self.times[-self.window:]))
            pred = cm.dptree_time(self.p, self.grad_bytes, self.num_blocks,
                                  self.model)
            if pred > 0 and med > self.threshold * pred:
                # effective alpha grew: re-solve with alpha_hat = alpha*ratio
                ratio = med / pred
                scaled = cm.CommModel(self.model.alpha * ratio,
                                      self.model.beta, self.model.gamma)
                self.num_blocks = max(1, cm.optimal_blocks(
                    self.p, self.grad_bytes, scaled, "dptree"))
                self.times.clear()
        return self.num_blocks


def run_with_restarts(loop_fn: Callable[[int], dict], max_restarts: int = 3):
    """Supervise ``loop_fn(attempt)``; on HostFailure restart from the latest
    checkpoint (loop_fn is responsible for restore-on-entry). Returns the
    final result dict with a ``restarts`` count."""
    attempt = 0
    while True:
        try:
            out = loop_fn(attempt)
            out["restarts"] = attempt
            return out
        except HostFailure:
            attempt += 1
            if attempt > max_restarts:
                raise
